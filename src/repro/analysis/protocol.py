"""Halo-protocol verifier: prove a compiled exchange plan correct without
running a step.

The data-plane contract of the sharded exchange (paper §2/§3.3): every
cross-rank ghost fill travels as exactly one p2p message per (neighboring
rank pair, field); the sender's gather spec and the receiver's scatter spec
describe the *same* payload byte for byte; every ghost cell that has a
neighbor is filled exactly once per exchange; and nothing reads or writes out
of bounds of the per-level arena buffers. The runtime conformance suite
checks this one scenario at a time by stepping; this module proves it for a
built plan by pure index arithmetic:

* **pairwise matching** — every message's rank pair is a process-graph
  neighbor pair, and the reverse message exists (no orphan sends: touching
  blocks see each other's ghosts from both sides);
* **byte symmetry** — ``nbytes == num_cells * lead * itemsize`` and the
  gather rows, scatter rows and declared cell count all agree, so sender and
  receiver walk identical payload layouts;
* **bounds** — every gather/scatter slot exists in the owning rank's slot
  map and every flat cell id lies inside the ghosted block box;
* **direction** — gathers read only *interior* cells (ghost regions are
  clipped to the neighbor's own box), scatters write only *ghost* cells;
* **coverage** — the union of intra-rank copies and incoming message
  scatters equals, exactly and without duplicates, an independent
  recomputation of every block's ghost-ring targets from the
  :func:`~repro.lbm.halo.ghost_regions` geometry oracle.

:func:`sweep_topologies` builds the weak-scaled 3-level benchmark forests
(the 1/4/13-rank conformance topologies) and verifies their compiled plans —
no step execution, no jax — and cross-checks the compiled per-pair byte
counts against the independently built host-plan (:class:`RankHaloPlan`)
patch bytes, so the Table-1 traffic accounting is mode-independent by
construction.
"""

from __future__ import annotations

import numpy as np

from ..core.forest import BlockForest
from ..lbm.halo import (
    CompiledGhostPlan,
    CompiledRankHaloPlan,
    _field_groups,
    _flat_cells,
    _srange,
    ghost_regions,
)
from .findings import Finding

__all__ = [
    "verify_compiled_rank_plan",
    "verify_ghost_plan",
    "rank_slot_map",
    "build_sweep_topology",
    "sweep_topologies",
]


def _fail(path: str, message: str) -> Finding:
    return Finding(
        checker="protocol", severity="error", path=path, line=0, message=message
    )


class _FieldMeta:
    """Per-field geometry: ghosted dims, flat cell count, interior predicate,
    payload row width and itemsize."""

    def __init__(self, spec, fields: tuple[str, ...]):
        self.ghost: dict[str, int] = {}
        self.dims: dict[str, tuple[int, int, int]] = {}
        self.lead: dict[str, int] = {}
        self.itemsize: dict[str, int] = {}
        self.cells = spec.cells
        for sp, names in _field_groups(spec, fields):
            for name in names:
                g = sp.ghost
                self.ghost[name] = g
                self.dims[name] = tuple(c + 2 * g for c in spec.cells)
        from ..core.fields import FieldRegistry

        if isinstance(spec, FieldRegistry):
            for name in fields:
                fs = spec.fields[name]
                self.lead[name] = int(np.prod(fs.shape, dtype=np.int64)) if fs.shape else 1
                self.itemsize[name] = np.dtype(fs.dtype).itemsize
        else:
            for name in fields:
                self.lead[name] = spec.lattice.Q if name == "pdf" else 1
                self.itemsize[name] = np.dtype(spec.dtype).itemsize

    def ncells(self, field: str) -> int:
        dx, dy, dz = self.dims[field]
        return dx * dy * dz

    def interior_mask(self, field: str, cell: np.ndarray) -> np.ndarray:
        """True where the flat ghosted cell id addresses an interior cell."""
        g = self.ghost[field]
        dx, dy, dz = self.dims[field]
        x = cell // (dy * dz)
        y = (cell // dz) % dy
        z = cell % dz
        cx, cy, cz = self.cells
        return (
            (x >= g) & (x < g + cx)
            & (y >= g) & (y < g + cy)
            & (z >= g) & (z < g + cz)
        )


def _expected_targets(
    forest: BlockForest,
    spec,
    fields: tuple[str, ...],
    levels: set[int] | None,
    slot_of,
) -> dict[tuple, list[np.ndarray]]:
    """Independent recomputation of every ghost-ring target from the geometry
    oracle: (owner, field, level) -> flat (slot, cell) encodings."""
    geom = forest.geom
    by_id = {b.bid: b for b in forest.all_blocks()}
    out: dict[tuple, list[np.ndarray]] = {}
    for blk in by_id.values():
        if levels is not None and blk.level not in levels:
            continue
        for nbid in blk.neighbors:
            nb = by_id[nbid]
            for sp, names in _field_groups(spec, fields):
                reg = ghost_regions(geom, sp, blk, nbid, nb.level)
                if reg is None:
                    continue
                target, _ = reg
                dims = tuple(c + 2 * sp.ghost for c in spec.cells)
                cells = _flat_cells(
                    dims, _srange(target[0]), _srange(target[1]), _srange(target[2])
                ).ravel()
                slot = slot_of(blk)
                enc = np.int64(slot) * (dims[0] * dims[1] * dims[2]) + cells
                for name in names:
                    out.setdefault((blk.owner, name, blk.level), []).append(enc)
    return out


def _check_segments(
    path: str,
    meta: _FieldMeta,
    field: str,
    segs,
    slot_sets: dict[int, set[int]],
    *,
    side: str,
    findings: list[Finding],
) -> None:
    """Bounds + direction checks for gather or scatter segments.

    ``segs``: iterables of (level, slot_arr, cell_arr, kindlabel)."""
    D = meta.ncells(field)
    for level, slot, cell, label in segs:
        ok_slots = slot_sets.get(level, set())
        bad = set(np.unique(slot).tolist()) - ok_slots
        if bad:
            findings.append(_fail(
                path,
                f"{side} segment ({field}, level {level}, {label}): slots "
                f"{sorted(bad)} not in the owning rank's level-{level} slot map",
            ))
        if cell.size and (cell.min() < 0 or cell.max() >= D):
            findings.append(_fail(
                path,
                f"{side} segment ({field}, level {level}, {label}): cell ids "
                f"outside [0, {D}) for the ghosted block box {meta.dims[field]}",
            ))
            continue
        interior = meta.interior_mask(field, cell.reshape(-1))
        if side == "gather" and not interior.all():
            findings.append(_fail(
                path,
                f"gather segment ({field}, level {level}, {label}) reads "
                f"{int((~interior).sum())} ghost cells — senders must read "
                "interior data only (ghost regions are clipped to the "
                "neighbor's own box)",
            ))
        if side == "scatter" and interior.any():
            findings.append(_fail(
                path,
                f"scatter segment ({field}, level {level}, {label}) writes "
                f"{int(interior.sum())} interior cells — a halo exchange may "
                "only fill the ghost ring",
            ))


def verify_compiled_rank_plan(
    forest: BlockForest,
    spec,
    plan: CompiledRankHaloPlan,
    rank_slots: dict[int, dict[int, dict[int, int]]],
    *,
    path: str = "<rank-halo-plan>",
) -> list[Finding]:
    """Statically prove a :class:`CompiledRankHaloPlan` implements the halo
    protocol (see module docstring for the checked properties). Returns an
    empty list iff the plan is correct."""
    findings: list[Finding] = []
    meta = _FieldMeta(spec, plan.fields)
    slot_sets = {
        r: {l: set(m.values()) for l, m in per.items()} for r, per in rank_slots.items()
    }
    neighbor_ranks = {r: set(forest.neighbor_ranks(r)) for r in rank_slots}

    msg_keys = {m.key for m in plan.messages}
    for m in plan.messages:
        mpath = f"{path}:msg[{m.src_rank}->{m.dst_rank}:{m.field}]"
        if m.src_rank == m.dst_rank:
            findings.append(_fail(mpath, "self-message: intra-rank fills must be local ops"))
        if m.dst_rank not in neighbor_ranks.get(m.src_rank, set()):
            findings.append(_fail(
                mpath,
                f"rank pair ({m.src_rank}, {m.dst_rank}) is not a process-"
                "graph neighbor pair — stepping traffic must be next-neighbor "
                "only (paper §2)",
            ))
        if (m.dst_rank, m.src_rank, m.field) not in msg_keys:
            findings.append(_fail(
                mpath,
                f"orphan send: no reverse message {m.dst_rank}->{m.src_rank} "
                f"for field '{m.field}' (touching blocks must exchange ghosts "
                "in both directions)",
            ))
        gather_rows = sum(int(np.asarray(cell).shape[0]) for _, _, _, cell in m.gather)
        scatter_rows = sum(n for _, _, _, n in m.scatter)
        scatter_cells = sum(int(cell.size) for _, _, cell, _ in m.scatter)
        if not (gather_rows == scatter_rows == scatter_cells == m.num_cells):
            findings.append(_fail(
                mpath,
                f"payload layout mismatch: gather rows {gather_rows}, scatter "
                f"rows {scatter_rows}/{scatter_cells}, declared num_cells "
                f"{m.num_cells} — sender and receiver would walk different "
                "payloads",
            ))
        expected_bytes = m.num_cells * meta.lead[m.field] * meta.itemsize[m.field]
        if m.nbytes != expected_bytes:
            findings.append(_fail(
                mpath,
                f"byte asymmetry: declared nbytes {m.nbytes} != num_cells * "
                f"lead * itemsize = {expected_bytes} — the fabric accounting "
                "would diverge from the payload",
            ))
        for level, kind, slot, cell in m.gather:
            if kind == "fine" and (cell.ndim != 2 or cell.shape[1] != 8):
                findings.append(_fail(
                    mpath,
                    f"fine gather segment (level {level}) must carry (N, 8) "
                    f"octet indices, got shape {cell.shape}",
                ))
        _check_segments(
            mpath, meta, m.field,
            [(lvl, slot, cell, kind) for lvl, kind, slot, cell in m.gather],
            slot_sets.get(m.src_rank, {}), side="gather", findings=findings,
        )
        _check_segments(
            mpath, meta, m.field,
            [(lvl, slot, cell, "scatter") for lvl, slot, cell, _ in m.scatter],
            slot_sets.get(m.dst_rank, {}), side="scatter", findings=findings,
        )

    for rank, local in plan.local.items():
        lpath = f"{path}:local[rank {rank}]"
        for op in local.ops:
            _check_segments(
                lpath, meta, op.field,
                [(op.src_level, op.src_slot, op.src_cell, op.kind)],
                slot_sets.get(rank, {}), side="gather", findings=findings,
            )
            _check_segments(
                lpath, meta, op.field,
                [(op.dst_level, op.dst_slot, op.dst_cell, op.kind)],
                slot_sets.get(rank, {}), side="scatter", findings=findings,
            )

    # coverage: local scatters + incoming message scatters == the geometry
    # oracle's ghost-ring targets, exactly once each
    levels = None if plan.levels is None else set(plan.levels)
    expected = _expected_targets(
        forest, spec, plan.fields, levels,
        lambda blk: rank_slots[blk.owner][blk.level][blk.bid],
    )
    actual: dict[tuple, list[np.ndarray]] = {}

    def add_actual(rank: int, field: str, level: int, slot: np.ndarray, cell: np.ndarray):
        enc = slot.astype(np.int64) * meta.ncells(field) + cell.astype(np.int64)
        actual.setdefault((rank, field, level), []).append(enc)

    for rank, local in plan.local.items():
        for op in local.ops:
            add_actual(rank, op.field, op.dst_level, op.dst_slot, op.dst_cell)
    for m in plan.messages:
        for level, slot, cell, _ in m.scatter:
            add_actual(m.dst_rank, m.field, level, slot, cell)

    for key in sorted(set(expected) | set(actual)):
        rank, field, level = key
        exp = np.sort(np.concatenate(expected.get(key, [np.empty(0, np.int64)])))
        act = np.sort(np.concatenate(actual.get(key, [np.empty(0, np.int64)])))
        if exp.shape == act.shape and np.array_equal(exp, act):
            continue
        kpath = f"{path}:coverage[rank {rank}, {field}, level {level}]"
        missing = np.setdiff1d(exp, act).size
        extra = np.setdiff1d(act, exp).size
        dupes = act.size - np.unique(act).size
        findings.append(_fail(
            kpath,
            f"ghost-ring coverage mismatch: {missing} expected ghost cells "
            f"never filled, {extra} writes outside the expected ring, "
            f"{dupes} duplicate writes (expected {exp.size}, got {act.size})",
        ))
    return findings


def verify_ghost_plan(
    forest: BlockForest,
    spec,
    plan: CompiledGhostPlan,
    slots: dict[int, dict[int, int]],
    *,
    path: str = "<ghost-plan>",
) -> list[Finding]:
    """Single-arena variant (the fused engine's intra-rank plan): bounds,
    gather/scatter direction, and exact ghost-ring coverage."""
    findings: list[Finding] = []
    meta = _FieldMeta(spec, plan.fields)
    slot_sets = {l: set(m.values()) for l, m in slots.items()}
    for op in plan.ops:
        _check_segments(
            path, meta, op.field,
            [(op.src_level, op.src_slot, op.src_cell, op.kind)],
            slot_sets, side="gather", findings=findings,
        )
        _check_segments(
            path, meta, op.field,
            [(op.dst_level, op.dst_slot, op.dst_cell, op.kind)],
            slot_sets, side="scatter", findings=findings,
        )
    levels = None if plan.levels is None else set(plan.levels)
    expected = _expected_targets(
        forest, spec, plan.fields, levels,
        lambda blk: slots[blk.level][blk.bid],
    )
    actual: dict[tuple, list[np.ndarray]] = {}
    for op in plan.ops:
        enc = op.dst_slot.astype(np.int64) * meta.ncells(op.field) + op.dst_cell.astype(np.int64)
        actual.setdefault((None, op.field, op.dst_level), []).append(enc)
    expected = {(None, f, l): v for (_, f, l), v in expected.items()}
    for key in sorted(set(expected) | set(actual), key=str):
        _, field, level = key
        exp = np.sort(np.concatenate(expected.get(key, [np.empty(0, np.int64)])))
        act = np.sort(np.concatenate(actual.get(key, [np.empty(0, np.int64)])))
        if not (exp.shape == act.shape and np.array_equal(exp, act)):
            findings.append(_fail(
                f"{path}:coverage[{field}, level {level}]",
                f"ghost-ring coverage mismatch: expected {exp.size} target "
                f"cells, plan scatters {act.size} "
                f"({np.setdiff1d(exp, act).size} missing, "
                f"{np.setdiff1d(act, exp).size} extra)",
            ))
    return findings


# -- topology sweep ----------------------------------------------------------------


def rank_slot_map(forest: BlockForest) -> dict[int, dict[int, dict[int, int]]]:
    """Deterministic rank -> level -> bid -> slot assignment (sorted bids),
    the shape :func:`~repro.lbm.halo.compile_rank_halo_plan` consumes."""
    per: dict[int, dict[int, list[int]]] = {}
    for b in forest.all_blocks():
        per.setdefault(b.owner, {}).setdefault(b.level, []).append(b.bid)
    return {
        r: {l: {bid: i for i, bid in enumerate(sorted(bids))} for l, bids in levels.items()}
        for r, levels in per.items()
    }


def build_sweep_topology(nranks: int, *, blocks_per_rank: int = 8) -> BlockForest:
    """The weak-scaled 3-level benchmark forest (mirrors
    ``benchmarks.scenario.build_scenario``), built through the real AMR
    pipeline — topology only, no field data, no stepping."""
    from ..core import (
        AMRPipeline,
        BlockDataRegistry,
        Comm,
        ForestGeometry,
        SFCBalancer,
        make_uniform_forest,
    )

    target_roots = max(1, nranks * blocks_per_rank // 16)
    rx = max(1, int(round(target_roots ** (1 / 3))))
    ry = max(1, int(round((target_roots / rx) ** 0.5)))
    rz = max(1, target_roots // (rx * ry))
    geom = ForestGeometry(root_grid=(rx, ry, rz), max_level=10)
    forest = make_uniform_forest(geom, nranks, level=0)
    comm = Comm(nranks)
    pipe = AMRPipeline(balancer=SFCBalancer(), registry=BlockDataRegistry.trivial())

    def refine_corner(rank, blocks):
        out = {}
        for bid, blk in blocks.items():
            x0, _, _, _, _, z1 = geom.aabb(bid)
            full = 1 << geom.max_level
            if z1 >= rz * full and x0 < (rx * full) // 2 and blk.level < 2:
                out[bid] = blk.level + 1
        return out

    forest, _ = pipe.run_cycle(forest, comm, refine_corner)
    forest, _ = pipe.run_cycle(forest, comm, refine_corner)
    return forest


def sweep_topologies(
    ranks: tuple[int, ...] = (1, 4, 13),
    *,
    cells: tuple[int, int, int] = (8, 8, 8),
    cross_check_host_bytes: bool = True,
) -> list[Finding]:
    """Verify the compiled rank-halo plan of each sweep topology; optionally
    cross-check compiled per-pair byte counts against the independently built
    host plan's patch bytes (``RankHaloPlan.nbytes``)."""
    from ..lbm.grid import LBMBlockSpec, make_lbm_fields
    from ..lbm.halo import build_rank_halo_plan, compile_rank_halo_plan

    findings: list[Finding] = []
    fields = ("pdf", "mask")
    for n in ranks:
        tpath = f"<topology:{n}ranks>"
        forest = build_sweep_topology(n)
        spec = LBMBlockSpec(cells=cells, ghost=1)
        registry = make_lbm_fields(spec)
        rank_slots = rank_slot_map(forest)
        plan = compile_rank_halo_plan(forest, registry, rank_slots, fields=fields)
        findings.extend(
            verify_compiled_rank_plan(forest, registry, plan, rank_slots, path=tpath)
        )
        if n > 1 and not plan.messages:
            findings.append(_fail(
                tpath, "multi-rank topology produced no cross-rank messages"
            ))
        if cross_check_host_bytes:
            for b in forest.all_blocks():
                b.data["pdf"] = np.zeros(spec.pdf_shape, dtype=spec.dtype)
                b.data["mask"] = np.zeros(spec.mask_shape, dtype=np.int32)
            host = build_rank_halo_plan(forest, registry, fields=fields)
            compiled_pair_bytes: dict[tuple[int, int], int] = {}
            for m in plan.messages:
                key = (m.src_rank, m.dst_rank)
                compiled_pair_bytes[key] = compiled_pair_bytes.get(key, 0) + m.nbytes
            if compiled_pair_bytes != dict(host.nbytes):
                findings.append(_fail(
                    tpath,
                    "compiled per-pair byte counts diverge from the host "
                    f"plan's patch bytes: compiled={compiled_pair_bytes} "
                    f"host={dict(host.nbytes)} — Table-1 traffic would be "
                    "mode-dependent",
                ))
    return findings
