"""Per-path lint configuration, read from ``pyproject.toml``.

The ``[tool.repro_lint]`` tables declare what each checker covers — the
designated hot-path modules for the host-transfer lint, the stepping-path
roots and control-plane exclusions for the collective-free check, the
donation factories, and the per-engine compile budgets the retrace sentinel
enforces. :data:`DEFAULTS` mirrors the committed ``pyproject.toml`` so the
checkers keep working when invoked on a tree without the section (fixtures,
external checkouts); anything present in ``pyproject.toml`` overrides the
default key-by-key.

``tomllib`` only exists on Python 3.11+; the repo supports 3.10, so a tiny
fallback parser covers the TOML subset these tables use (string/int/float/
bool scalars, homogeneous arrays, dotted table headers, inline tables are
NOT needed). No third-party dependency is involved either way.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from pathlib import Path

__all__ = ["LintConfig", "load_config", "DEFAULTS"]


DEFAULTS: dict = {
    "baseline": "tools/repro_lint_baseline.json",
    "host_transfer": {
        # the designated hot-path modules: every implicit device->host sync
        # here is either a bug or carries a documented host-ok annotation
        "paths": [
            "src/repro/lbm/engines.py",
            "src/repro/lbm/halo.py",
            "src/repro/kernels/lbm_collide",
            "src/repro/serving/ensemble.py",
        ],
    },
    "donation": {
        # modules scanned for use-after-donate (tests included: un-audited
        # reads of donated pdf buffers in test helpers are real bugs)
        "paths": ["src/repro", "tests"],
        # calls whose result is a donating program (donate_argnums on the
        # pdf operand): reading a buffer after passing it to one is a
        # use-after-donate unless the same statement rebinds it
        "factories": [
            "make_fused_superstep",
            "make_rank_absorb",
            "make_rank_absorb_split",
            "_fused_program",
        ],
    },
    "collective": {
        # stepping-path roots: the import closure of these modules must be
        # collective-free (the static twin of the Table-1 runtime tests)
        "stepping_modules": [
            "repro.lbm.engines",
            "repro.lbm.halo",
            "repro.kernels.lbm_collide.ops",
            "repro.kernels.lbm_collide.lbm_collide",
            "repro.kernels.lbm_collide.ref",
            "repro.serving.ensemble",
        ],
        # control-plane modules: reachable via package imports but only ever
        # invoked from adapt()/AMR cycles, where collectives are sanctioned
        # (balancing, marking, proxy migration, checkpoint codecs)
        "exclude": [
            "repro.core.balancing",
            "repro.core.refine",
            "repro.core.pipeline",
            "repro.core.proxy",
            "repro.core.migration",
            "repro.core.checkpoint",
            "repro.core.resilience",
        ],
        # collective-class call names. ppermute/collective_permute ARE
        # listed: they are the sanctioned p2p halo fabric (a partial
        # permutation has no fan-in), but every call site must say so —
        # exempt-with-reason via '# repro: collective-ok(...)' or live in
        # the fabric provider itself, so a stray ppermute outside the
        # audited fabric still surfaces
        "collectives": [
            "psum",
            "pmean",
            "pmax",
            "pmin",
            "all_gather",
            "allgather",
            "all_reduce",
            "allreduce",
            "all_to_all",
            "alltoall",
            "reduce_scatter",
            "ppermute",
            "collective_permute",
        ],
    },
    "retrace": {
        "paths": ["src/repro"],
        # expected-compile-count budgets per engine for the canonical
        # conformance scenario (2 coarse steps + 1 AMR event at 4 ranks);
        # enforced by tests/test_analysis.py through RetraceSentinel
        "budgets": {"fused": 12, "fused_sharded": 40},
    },
    "protocol": {
        # rank counts the CLI topology sweep verifies (matching the 1/4/13
        # conformance topologies)
        "ranks": [1, 4, 13],
    },
}


@dataclass
class LintConfig:
    repo_root: Path
    raw: dict = field(default_factory=dict)

    def section(self, name: str) -> dict:
        merged = dict(DEFAULTS.get(name, {}))
        merged.update(self.raw.get(name, {}))
        return merged

    @property
    def baseline_path(self) -> Path:
        return self.repo_root / self.raw.get("baseline", DEFAULTS["baseline"])


def _parse_toml(text: str) -> dict:
    try:
        import tomllib  # Python 3.11+

        return tomllib.loads(text)
    except ModuleNotFoundError:  # pragma: no cover - 3.10 fallback
        return _parse_toml_subset(text)


def _parse_scalar(tok: str):
    tok = tok.strip()
    if tok.startswith(('"', "'")):
        return tok[1:-1]
    if tok in ("true", "false"):
        return tok == "true"
    try:
        return int(tok)
    except ValueError:
        pass
    try:
        return float(tok)
    except ValueError:
        return tok


def _split_items(body: str) -> list[str]:
    """Split a bracketed body on top-level commas (strings may hold commas)."""
    items, cur, quote = [], "", None
    for ch in body:
        if quote:
            cur += ch
            if ch == quote:
                quote = None
        elif ch in "\"'":
            quote = ch
            cur += ch
        elif ch == ",":
            items.append(cur)
            cur = ""
        else:
            cur += ch
    if cur.strip():
        items.append(cur)
    return items


def _parse_toml_subset(text: str) -> dict:  # pragma: no cover - 3.10 fallback
    """Minimal TOML for the repro_lint tables (see module docstring)."""
    root: dict = {}
    table = root
    pending_key: str | None = None
    pending_buf = ""
    for raw_line in text.splitlines():
        line = raw_line.strip()
        if pending_key is not None:
            pending_buf += " " + line
            if line.endswith("]"):
                table[pending_key] = [
                    _parse_scalar(t) for t in _split_items(pending_buf.strip()[1:-1])
                ]
                pending_key = None
            continue
        if not line or line.startswith("#"):
            continue
        if line.startswith("["):
            table = root
            for part in line.strip("[]").split("."):
                table = table.setdefault(part.strip().strip('"'), {})
            continue
        if "=" not in line:
            continue
        key, _, val = line.partition("=")
        key, val = key.strip().strip('"'), val.split(" #")[0].strip()
        if val.startswith("[") and not val.endswith("]"):
            pending_key, pending_buf = key, val
        elif val.startswith("["):
            table[key] = [_parse_scalar(t) for t in _split_items(val[1:-1])]
        elif val.startswith("{"):
            inline: dict = {}
            for item in _split_items(val[1:-1]):
                k, _, v = item.partition("=")
                inline[k.strip().strip('"')] = _parse_scalar(v)
            table[key] = inline
        else:
            table[key] = _parse_scalar(val)
    return root


def load_config(repo_root: Path) -> LintConfig:
    pyproject = repo_root / "pyproject.toml"
    raw: dict = {}
    if pyproject.exists():
        data = _parse_toml(pyproject.read_text())
        raw = data.get("tool", {}).get("repro_lint", {})
    return LintConfig(repo_root=repo_root, raw=raw)
