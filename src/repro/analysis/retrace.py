"""Retrace sentinel: count actual jit traces and enforce compile budgets.

The static scan in :mod:`repro.analysis.checkers` catches the *patterns* that
cause unstable compile caches; this module measures the *fact*: every
``jax.jit`` created while a :class:`RetraceSentinel` is active gets a
counting shim around its wrapped Python function, so each trace (the wrapped
function's Python body runs once per cache miss) increments a counter keyed
by the function's qualname. An engine whose plan-cache versioning works
compiles a bounded number of programs per scenario (per arena version, not
per step); the per-engine budgets live in ``[tool.repro_lint.retrace]`` and
``tests/test_analysis.py`` holds the line.

The sentinel patches ``jax.jit`` only for the duration of the ``with`` block
and restores it on exit, even on error. The repo always calls ``jax.jit``
through the module attribute, so the patch sees every program build; programs
built *before* entering the sentinel keep their original uncounted wrappers
(that is the point — a warm cache must not trace at all).
"""

from __future__ import annotations

import functools

from ..telemetry import get_tracer
from .findings import Finding

__all__ = ["RetraceSentinel", "budget_findings"]

_TR = get_tracer()


class RetraceSentinel:
    """Context manager instrumenting ``jax.jit`` to count traces."""

    def __init__(self):
        self.counts: dict[str, int] = {}
        self._orig = None

    def total(self) -> int:
        return sum(self.counts.values())

    def _count_wrap(self, fun):
        name = getattr(fun, "__qualname__", None) or repr(fun)

        @functools.wraps(fun)
        def counting(*args, **kwargs):
            self.counts[name] = self.counts.get(name, 0) + 1
            # a cache miss is the compile event the trace timeline shows:
            # each trace lands on the compile track as an instant
            _TR.instant(f"jit:{name}", cat="compile")
            return fun(*args, **kwargs)

        return counting

    def __enter__(self):
        import jax

        self._orig = jax.jit
        orig = self._orig
        sentinel = self

        def counted_jit(fun=None, **kwargs):
            if fun is None:  # jax.jit(**kw) decorator-factory form
                return lambda f: counted_jit(f, **kwargs)
            return orig(sentinel._count_wrap(fun), **kwargs)

        jax.jit = counted_jit
        return self

    def __exit__(self, *exc):
        import jax

        jax.jit = self._orig
        return False


def budget_findings(label: str, counts: dict[str, int], budget: int) -> list[Finding]:
    """Compare measured trace counts against an engine's compile budget."""
    total = sum(counts.values())
    if total <= budget:
        return []
    worst = sorted(counts.items(), key=lambda kv: -kv[1])[:5]
    detail = ", ".join(f"{name}={n}" for name, n in worst)
    return [
        Finding(
            checker="retrace",
            severity="error",
            path=f"<retrace:{label}>",
            line=0,
            message=(
                f"engine '{label}' traced {total} times, budget is {budget} "
                f"(top tracers: {detail}) — a plan-cache version token is "
                "probably not keying a program cache, or a static arg is "
                "unstable"
            ),
            fix_hint="key program caches on arena.version; keep static args "
            "hashable and low-cardinality",
        )
    ]
