from .specs import (
    batch_pspecs,
    cache_pspecs,
    param_pspecs,
    opt_state_pspecs,
    BATCH_AXES,
)

__all__ = [
    "param_pspecs",
    "batch_pspecs",
    "cache_pspecs",
    "opt_state_pspecs",
    "BATCH_AXES",
]
