"""PartitionSpec rules for all architectures and input shapes.

Baseline layout = TP ("model" axis) x FSDP ("data" axis) x pure DP ("pod"):

* every weight matrix is sharded on "model" along its parallel dimension
  (column-parallel in, row-parallel out — Megatron style) *and* on "data"
  along the other dimension (FSDP storage sharding; XLA all-gathers per
  layer inside the scan);
* the "pod" axis only shards the batch: parameters are replicated across
  pods, so gradient all-reduces are the only inter-pod collectives —
  the slow inter-pod links see O(params/pod) traffic per step, not
  per-layer traffic;
* optimizer state (fp32 master + moments) inherits the parameter specs —
  with FSDP params this is full ZeRO sharding;
* MoE experts: expert dim on "model" when divisible (true EP: granite-moe
  32e/16) else d_ff on "model" (TP inside each expert: mixtral 8e/16);
* KV caches: batch on ("pod","data"), kv-heads on "model" — except
  ``long_500k`` (batch=1) where the *sequence* dim is sharded on
  ("pod","data") and decode becomes a distributed flash-decode.

GSPMD pads uneven dimensions (e.g. vocab 49155, kv-heads 2 on a 16-way
axis), so divisibility is not required for correctness.
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from ..configs.base import ArchConfig
from ..configs.shapes import ShapeConfig

__all__ = ["param_pspecs", "batch_pspecs", "cache_pspecs", "opt_state_pspecs", "BATCH_AXES"]

BATCH_AXES = ("pod", "data")  # present axes are filtered per mesh


def _ax(mesh_axes: tuple[str, ...], *names: str):
    """Axis tuple filtered to the axes the mesh actually has."""
    present = tuple(n for n in names if n in mesh_axes)
    if not present:
        return None
    return present if len(present) > 1 else present[0]


# default production-mesh axis sizes; callers pass the real ones
DEFAULT_AXIS_SIZES = {"pod": 2, "data": 16, "model": 16}


def _sanitize(spec: P, shape: tuple[int, ...], axis_sizes: dict[str, int]) -> P:
    """Explicit jit in_shardings require exact divisibility (GSPMD padding is
    only available to *internal* propagation) — drop axes that do not divide
    their dimension (the tensor is then replicated over them)."""
    out = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if entry is None:
            out.append(None)
            continue
        names = entry if isinstance(entry, tuple) else (entry,)
        prod = 1
        for n in names:
            prod *= axis_sizes.get(n, 1)
        if prod and dim % prod == 0:
            out.append(entry)
        else:
            out.append(None)
    return P(*out)


# -- parameters ---------------------------------------------------------------

_COL_IN = {  # (D_in, X_out): in-dim FSDP, out-dim TP
    "wq", "wk", "wv", "w_gate", "w_up", "w_ck", "w_cr", "w_r", "w_k", "w_v",
    "w_g", "in_proj", "w_lora_a",
}
_ROW_OUT = {"wo", "w_down", "w_cv", "out_proj", "w_o", "w_lora_b"}
_REPLICATED = {
    "scale", "bias", "A_log", "D_skip", "dt_bias", "norm_scale", "u", "w0",
    "ln_x_scale", "ln_x_bias", "conv_b", "mu_r", "mu_k", "mu_v", "mu_g",
    "mu_w", "mu_ck", "mu_cr",
}


def _leaf_spec(
    cfg: ArchConfig, path: tuple, leaf, mesh_axes, fsdp: bool = True,
    layout: str = "tp-fsdp",
) -> P:
    names = [p.key for p in path if hasattr(p, "key")]
    name = names[-1]
    ndim = len(leaf.shape)
    stacked = any(n in ("layers", "encoder", "cross") for n in names)
    lead = (None,) if stacked else ()
    # fsdp=False (serving layout): params replicated over "data" — decoding
    # has no optimizer state to shard and per-layer param all-gathers are
    # pure overhead at batch 1 token/step (§Perf pair 2, iteration 3)
    data = _ax(mesh_axes, "data") if fsdp else None
    model = _ax(mesh_axes, "model")
    if layout == "fsdp":
        # pure-FSDP layout: no tensor parallelism; the model axis becomes a
        # second data axis — params are storage-sharded over both and
        # gathered per layer (§Perf pair 1/3 beyond-paper iteration)
        data = _ax(mesh_axes, "data", "model") if fsdp else None
        model = None

    def pad(spec_tail: tuple) -> P:
        tail = lead + spec_tail
        assert len(tail) == ndim, (names, leaf.shape, tail)
        return P(*tail)

    if name == "embed":
        return P(model, data)
    if name == "head":
        return P(data, model)
    if name == "router":
        return pad((data, None))
    if "moe" in names and name in ("w_gate", "w_up"):
        if cfg.n_experts % 16 == 0:  # expert parallelism
            return pad((model, data, None))
        return pad((None, data, model))  # TP inside experts
    if "moe" in names and name == "w_down":
        if cfg.n_experts % 16 == 0:
            return pad((model, None, data))
        return pad((None, model, data))
    if name == "conv_w":
        return pad((None, model))
    if name in ("bq", "bk", "bv"):
        return pad((model,))
    if name in _REPLICATED:
        return pad((None,) * (ndim - len(lead)))
    if name in _COL_IN:
        return pad((data, model))
    if name in _ROW_OUT:
        return pad((model, data))
    # fallback: replicate
    return P(*((None,) * ndim))


def param_pspecs(
    cfg: ArchConfig,
    params_shapes: Any,
    mesh_axes: tuple[str, ...],
    axis_sizes: dict[str, int] | None = None,
    fsdp: bool = True,
    layout: str = "tp-fsdp",
) -> Any:
    """Spec tree matching the parameter tree (built from eval_shape output)."""
    sizes = axis_sizes or DEFAULT_AXIS_SIZES
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _sanitize(
            _leaf_spec(cfg, path, leaf, mesh_axes, fsdp, layout), leaf.shape, sizes
        ),
        params_shapes,
    )


def opt_state_pspecs(
    cfg: ArchConfig,
    opt_shapes: Any,
    mesh_axes: tuple[str, ...],
    axis_sizes: dict[str, int] | None = None,
    layout: str = "tp-fsdp",
) -> Any:
    """Optimizer state: step replicated; master/m/v inherit param specs."""
    sizes = axis_sizes or DEFAULT_AXIS_SIZES

    def spec(path, leaf):
        names = [p.key for p in path if hasattr(p, "key")]
        if names and names[0] == "step":
            return P()
        return _sanitize(
            _leaf_spec(cfg, path[1:], leaf, mesh_axes, True, layout), leaf.shape, sizes
        )

    return jax.tree_util.tree_map_with_path(spec, opt_shapes)


# -- batches ---------------------------------------------------------------------


def batch_pspecs(cfg: ArchConfig, shape: ShapeConfig, mesh_axes, layout: str = "tp-fsdp") -> dict:
    b = _ax(mesh_axes, "pod", "data") if layout != "fsdp" else _ax(mesh_axes, "pod", "data", "model")
    model = _ax(mesh_axes, "model") if layout != "fsdp" else None
    out: dict[str, P] = {"tokens": P(b, None), "labels": P(b, None)}
    if shape.kind == "decode":
        if shape.global_batch == 1:
            out = {"tokens": P(None, None), "labels": P(None, None)}
    if cfg.m_rope:
        out["positions"] = P(out["tokens"][0], None, None)
        out["frontend_embeds"] = P(out["tokens"][0], None, model)
    if cfg.is_encoder_decoder:
        out["enc_embeds"] = P(out["tokens"][0], None, model)
    return out


# -- caches -----------------------------------------------------------------------


def cache_pspecs(
    cfg: ArchConfig,
    shape: ShapeConfig,
    cache_shapes: Any,
    mesh_axes,
    axis_sizes: dict[str, int] | None = None,
) -> Any:
    """Spec tree matching init_cache's structure."""
    sizes = axis_sizes or DEFAULT_AXIS_SIZES
    bax = _ax(mesh_axes, "pod", "data")
    model = _ax(mesh_axes, "model")
    seq_shard = shape.global_batch == 1  # long_500k: shard the KV sequence

    model_size = sizes.get("model", 1)

    def spec(path, leaf):
        names = [p.key for p in path if hasattr(p, "key")]
        name = names[-1]
        if name == "pos":
            return P()
        if name in ("k", "v", "ek", "ev"):  # (L, B, T, Hkv, hd)
            if seq_shard:
                return P(None, None, bax, model, None)
            if cfg.n_kv % max(model_size, 1) == 0:
                return P(None, bax, None, model, None)
            # kv heads do not divide the model axis: shard the cache
            # *sequence* dim on it instead (distributed flash-decode) —
            # batch-only sharding leaves 36-241 GiB/device and a full
            # cache all-gather per step (§Perf pair 2).
            return P(None, bax, model, None, None)
        if name == "conv":  # (L, B, K-1, conv_dim)
            return P(None, bax if not seq_shard else None, None, model)
        if name == "ssm":  # (L, B, H, N, P)
            return P(None, bax if not seq_shard else None, model, None, None)
        if name == "wkv":  # (L, B, H, hd, hd)
            return P(None, bax if not seq_shard else None, model, None, None)
        if name in ("shift_t", "shift_c"):  # (L, B, D)
            return P(None, bax if not seq_shard else None, None)
        return P(*((None,) * len(leaf.shape)))

    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _sanitize(spec(path, leaf), leaf.shape, sizes), cache_shapes
    )
